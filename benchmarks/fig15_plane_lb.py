"""Fig 15 — multiplane load balancing under noise-induced asymmetry
(the Fig 16 testbed: 4 planes, 3 leaves x 16 NICs; planes 2/3 degraded to
25% uplinks).

* per-plane CC (SPX PLB) vs a single Global CC context: Global CC
  collapses >40-50% under asymmetry; SPX stays near baseline.
* message-size convergence: short bursts end before the PLB accumulates
  per-plane congestion signals (fresh CC state per burst).
* ESR (entropy-based source routing): entangled CC+LB loops oscillate.

All three sub-studies are experiments over `fig15_testbed` specs
(`repro.experiments.library`) — the testbed's trimmed planes are
`leaf_trim` faults, the burst pattern a `one2many` workload."""
from __future__ import annotations

from repro.experiments import get_experiment, run_experiment
from repro.experiments.library import STACK_NAMES

from .common import emit


def run() -> None:
    # --- per-plane CC vs Global CC, base vs asymmetric fabric ---
    rs = run_experiment(get_experiment("fig15_lb_asymmetry"))
    for row in rs.rows():
        scen = row["axis.scenario"]           # fig15_{kind}_{base|asym}
        kind = scen.split("_")[1]
        tag = scen.rsplit("_", 1)[1]
        emit(f"fig15.{kind}.{STACK_NAMES[row['nic']]}.{tag}", 0.0,
             f"per_nic_bw={row['extra']['per_nic_bw']:.3f}")

    # --- message-size convergence (fresh PLB state per burst) ---
    rs = run_experiment(get_experiment("fig15_msg_convergence"))
    for row in rs.rows():
        ms = row["axis.workloads[0].bytes_total"]
        emit(f"fig15c.convergence.msg{ms}slots", 0.0,
             f"normalized_bw={row['extra']['normalized_bw']:.3f}")

    # --- ESR oscillation ---
    rs = run_experiment(get_experiment("fig15_esr_oscillation"))
    for row in rs.rows():
        x = row["extra"]
        emit(f"fig15d.esr_oscillation.{STACK_NAMES[row['nic']]}", 0.0,
             f"bw_cv={x['bw_cv']:.3f},mean={x['mean_bw']:.2f}")


if __name__ == "__main__":
    run()
