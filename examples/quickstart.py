"""Quickstart: train a tiny LM with plane-split collectives, survive a
plane failure, and serve from the trained weights.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PlaneConfig
from repro.data import DataConfig, DataLoader
from repro.models import init_params, param_count
from repro.models.config import ModelConfig
from repro.parallel.sharding import local_ctx
from repro.train import Request, ServeEngine, Trainer, TrainerConfig


def main():
    cfg = ModelConfig(name="quickstart-2M", n_layers=4, d_model=128,
                      n_heads=4, n_kv_heads=2, head_dim=32, d_ff=512,
                      vocab=512, attn_chunk=64, remat="none")
    ctx = local_ctx()
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {cfg.name}, {param_count(params):,} params")

    tcfg = TrainerConfig(plane=PlaneConfig(n_planes=4, microchunks=16),
                         warmup_steps=2, total_steps=30)
    trainer = Trainer(cfg, ctx, tcfg, params)
    dl = DataLoader(DataConfig(vocab=cfg.vocab, seq_len=64,
                               global_batch=8))

    print("\n-- training; plane 2 fails at step 10, heals at step 20 --")
    for i, batch in zip(range(30), dl):
        if i == 10:
            trainer.inject_plane_failure(2)
        if i == 20:
            trainer.heal_plane(2)
        m = trainer.train_step({k: jnp.asarray(v)
                                for k, v in batch.items()})
        if i % 5 == 0 or i in (10, 11, 20):
            print(f"step {i:3d} loss {m['loss']:.3f} "
                  f"planes {m['planes_up']} eff_bw {m['plane_eff_bw']:.2f}")
    rec = trainer.failover.records[0]
    print(f"\nplane 2 failover converged in {rec.recovery_steps} steps "
          f"(budget: probe_timeout {tcfg.plane.probe_timeout} + "
          f"recovery {tcfg.plane.recovery_steps})")

    print("\n-- serving --")
    eng = ServeEngine(cfg, ctx, trainer.params, batch=4, max_len=96)
    reqs = [Request(i, np.arange(8, dtype=np.int32) + i, max_new=8)
            for i in range(4)]
    for r in eng.run(reqs):
        print(f"req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
