"""End-to-end driver: train the ~100M-parameter ``spx-100m`` config for a
few hundred steps with the full substrate (deterministic data pipeline,
AdamW + cosine schedule, plane-split gradient collectives, checkpointing,
HFT telemetry).

  PYTHONPATH=src python examples/train_e2e.py                # full
  PYTHONPATH=src python examples/train_e2e.py --smoke        # CI-scale

On a TPU pod this config is launched through repro.launch.train with the
production mesh; on this CPU container --smoke shrinks width (not
structure) so the example completes in minutes.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import PlaneConfig
from repro.data import DataConfig, DataLoader
from repro.models import init_params, param_count
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import local_ctx
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/spx100m_ckpt")
    args = ap.parse_args()

    cfg = get_config("spx-100m")
    if args.smoke:
        cfg = cfg.reduced(d_model=128, n_heads=4, head_dim=32, d_ff=512,
                          vocab=2048)
        args.steps = min(args.steps, 40)
        args.seq = 128
    ctx = local_ctx()
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"{cfg.name}: {param_count(params):,} params, "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")

    tcfg = TrainerConfig(
        plane=PlaneConfig(n_planes=4, microchunks=16),
        adamw=AdamWConfig(lr=6e-4),
        warmup_steps=max(args.steps // 20, 2), total_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 3, 10))
    trainer = Trainer(cfg, ctx, tcfg, params)
    dl = DataLoader(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch))
    first = None
    for i, batch in zip(range(args.steps), dl):
        m = trainer.train_step({k: jnp.asarray(v)
                                for k, v in batch.items()})
        first = first or m["loss"]
        if i % max(args.steps // 20, 1) == 0:
            print(f"step {i:4d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.2f} "
                  f"{m['step_time_s'] * 1e3:.0f} ms/step", flush=True)
    print(f"\nloss {first:.4f} -> {m['loss']:.4f} "
          f"({trainer.step} steps, ckpt at {args.ckpt_dir})")


if __name__ == "__main__":
    main()
