"""Fig 13 analogue: LLM training step-times under injected link flaps.

Two host-plane flaps then three fabric-tier flaps; SPX falls back to 3
planes within one iteration and restores instantly on heal — step time
stays stable throughout (no crash, no restart).

  PYTHONPATH=src python examples/failover_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PlaneConfig
from repro.core.telemetry import symmetry_check
from repro.data import DataConfig, DataLoader
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.parallel.sharding import local_ctx
from repro.train import Trainer, TrainerConfig


def main():
    cfg = ModelConfig(name="nemotron-proxy", n_layers=4, d_model=128,
                      n_heads=4, n_kv_heads=2, head_dim=32, d_ff=512,
                      vocab=1024, attn_chunk=64, remat="none")
    ctx = local_ctx()
    tcfg = TrainerConfig(plane=PlaneConfig(n_planes=4, microchunks=16),
                         warmup_steps=2, total_steps=60)
    trainer = Trainer(cfg, ctx, tcfg,
                      init_params(jax.random.PRNGKey(0), cfg))
    dl = DataLoader(DataConfig(vocab=cfg.vocab, seq_len=64,
                               global_batch=8))

    # flap schedule: (step, action, plane)
    flaps = {8: ("fail", 1), 14: ("heal", 1),
             22: ("fail", 1), 28: ("heal", 1)}
    print("step  loss    planes  eff_bw  comm_x")
    comm = []
    for i, batch in zip(range(40), dl):
        if i in flaps:
            act, plane = flaps[i]
            (trainer.inject_plane_failure if act == "fail"
             else trainer.heal_plane)(plane)
            print(f"--- {act} plane {plane} ---")
        m = trainer.train_step({k: jnp.asarray(v)
                                for k, v in batch.items()})
        # modeled comm slowdown = 1 / effective plane bandwidth
        slow = 1.0 / max(m["plane_eff_bw"], 1e-3)
        comm.append(slow)
        if i % 2 == 0 or i in flaps:
            print(f"{i:4d}  {m['loss']:.3f}  {m['planes_up']:4d}   "
                  f"{m['plane_eff_bw']:.2f}   {slow:.2f}x")

    comm = np.array(comm)
    # steady fallback slowdown: the failed-plane steps AFTER the PLB
    # converged (detection itself momentarily stalls the stream — Fig 12)
    fallback = np.concatenate([comm[11:14], comm[25:28]])
    print(f"\ncomm slowdown: pristine 1.00x, steady 3-plane fallback "
          f"{np.median(fallback):.2f}x (paper: 4/3 = 1.33x)")
    recs = trainer.failover.records
    print(f"failovers: {[(r.plane, r.recovery_steps) for r in recs]}")

    # symmetry-group telemetry over the final plane loads (§5.1)
    from repro.core import stream_report
    rep = stream_report(trainer.params, tcfg.plane,
                        np.ones(4) / 4)
    sym = symmetry_check("planes", rep.bytes_per_plane, cv_tol=0.1)
    print(f"plane symmetry (healthy): uniform={sym.uniform} "
          f"cv={sym.cv:.3f}")


if __name__ == "__main__":
    main()
