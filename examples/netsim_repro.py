"""Reproduce the paper's headline network results in one script.

  PYTHONPATH=src python examples/netsim_repro.py
"""
import numpy as np

from repro.netsim import LeafSpine, all2all, bisection_pairs, Flow
from repro.netsim.sim import SimConfig, run_sim


def main():
    rng = np.random.default_rng(0)

    print("== Fig 8: bisection under max load (64 endpoints) ==")
    t0 = LeafSpine(n_leaves=8, n_spines=8, hosts_per_leaf=8, n_planes=1)
    flows = bisection_pairs(t0, range(t0.n_hosts), rng)
    for name, nic, routing in (("ETH (ECMP+DCQCN)", "dcqcn", "ecmp"),
                               ("SPX (AR + SPX-CC)", "spx", "ar")):
        r = run_sim(t0.copy(), flows,
                    SimConfig(slots=500, nic=nic, routing=routing, seed=1))
        gp = r.mean_goodput
        print(f"  {name:20s} p01={np.quantile(gp, 0.01) * 100:5.1f}% "
              f"median={np.median(gp) * 100:5.1f}% of line rate, "
              f"p99 lat {np.quantile(r.rtt[250:], 0.99):5.1f} us")

    print("\n== Fig 9: victim All2All next to a noise All2All ==")
    for name, nic, routing in (("ETH", "dcqcn", "ecmp"),
                               ("SPX", "spx", "ar")):
        victims = list(range(0, 64, 4))
        noise = [h for h in range(64) if h % 4 != 0]
        fl = (all2all(t0, victims, group="victim") +
              all2all(t0, noise, group="noise"))
        r = run_sim(t0.copy(), fl,
                    SimConfig(slots=400, nic=nic, routing=routing, seed=2))
        vi = r.groups.index("victim")
        v = r.mean_goodput[r.group_of == vi].reshape(16, 15).sum(1)
        print(f"  {name}: victim rank bandwidth = {v.mean() * 100:.1f}% "
              f"of line rate")

    print("\n== Fig 12: host-plane flap, hardware PLB vs software LB ==")

    def ev(t, topo):
        if t == 50:
            topo.fail_access(1, 0)

    for name, nic, delay, slots in (("HW PLB", "spx", 0.0, 600),
                                    ("SW LB", "swlb", 1000.0, 12000)):
        t = LeafSpine(n_leaves=2, n_spines=2, hosts_per_leaf=4,
                      n_planes=4, access_cap=0.25)
        r = run_sim(t, [Flow(0, 4, 1.0)],
                    SimConfig(slots=slots, slot_us=100.0, nic=nic,
                              routing="ar", sw_lb_delay_ms=delay, seed=3),
                    events=ev)
        g = r.goodput[:, 0]
        post = np.flatnonzero((np.arange(len(g)) > 50) & (g >= 0.675))
        rec = (post[0] - 50) * 0.1 if len(post) else float("inf")
        print(f"  {name}: recovery {rec:8.1f} ms -> steady "
              f"{g[-5:].mean() * 100:.0f}% (3 of 4 planes)")


if __name__ == "__main__":
    main()
