"""Declarative scenario engine walkthrough.

  PYTHONPATH=src python examples/scenario_demo.py

1. Pull a named scenario from the registry and run it.
2. Compose a custom spec (two tenants + a mid-run spine cascade) in a few
   lines — no bespoke benchmark script needed.
3. Sweep a scenario over a (seed × stack) grid with the batched runner.
4. Re-run the sweep on the JAX backend — one vmapped computation per
   (routing, nic) group instead of a process pool.
5. The Experiment API: sweep *arbitrary* spec axes (fault fraction ×
   plane count), query the columnar ResultSet, and re-run against the
   content-hashed run cache — the second pass never simulates.
"""
import tempfile
import time

from repro.experiments import Axis, Experiment, product, run_experiment
from repro.scenarios import (FaultSpec, ScenarioSpec, SimSpec, SweepGrid,
                             TenantSpec, TopologySpec, WorkloadSpec,
                             get_scenario, metrics_csv, run_point, sweep)


def main() -> None:
    print("== 1. a registry scenario: Fig 9 victim/noise isolation ==")
    m = run_point(get_scenario("fig9_victim_noise"))
    for tenant, bw in sorted(m.tenant_mean.items()):
        print(f"  {tenant:8s} mean flow goodput = {bw:.3f} of line rate")
    print(f"  isolation index (Jain, demand-normalized) = "
          f"{m.isolation_index:.3f}")

    print("\n== 2. a custom spec: storage noise + spine cascade ==")
    spec = ScenarioSpec(
        name="demo_custom",
        topo=TopologySpec(n_leaves=8, n_spines=8, hosts_per_leaf=8),
        tenants=(TenantSpec("train", placement="interleave", stride=2,
                            n_hosts=32),
                 TenantSpec("storage", placement="remainder")),
        workloads=(WorkloadSpec("all2all", tenant="train"),
                   WorkloadSpec("storage", tenant="storage", demand=0.2,
                                fanout=2)),
        faults=(FaultSpec("cascade", start_slot=120, period=60,
                          spines=(7, 6)),),
        sim=SimSpec(slots=320, routing="war"))
    m = run_point(spec)
    print(f"  train goodput  = {m.tenant_mean['train']:.3f}")
    print(f"  storage goodput= {m.tenant_mean['storage']:.3f}")
    for slot, label, rec in m.recovery_slots:
        rec_s = f"{rec} slots" if rec >= 0 else "not within window"
        print(f"  fault {label:12s} at slot {slot}: recovered in {rec_s}")
    print(f"  symmetry cv={m.symmetry_cv:.3f} "
          f"outlier spines={m.symmetry_outliers}")

    print("\n== 3. multi-seed sweep: hardware vs software stack ==")
    grids = [(nic, routing, SweepGrid(seeds=(0, 1, 2), nics=(nic,),
                                      routings=(routing,), slots=200))
             for nic, routing in (("spx", "ar"), ("dcqcn", "ecmp"))]
    rows = []
    t0 = time.perf_counter()
    for _, _, grid in grids:
        rows += sweep("multi_tenant_75_25", grid)
    t_np = time.perf_counter() - t0
    print(metrics_csv(rows))

    print("\n== 4. the same sweep, JAX backend (single process) ==")
    rows_jx = []
    t0 = time.perf_counter()
    for _, _, grid in grids:
        rows_jx += sweep("multi_tenant_75_25", grid, backend="jax")
    t_jx = time.perf_counter() - t0
    agree = sum(a.to_row() == b.to_row() for a, b in zip(rows, rows_jx))
    print(f"  numpy pool {t_np:.2f}s vs jax {t_jx:.2f}s (incl. jit "
          f"compile); {agree}/{len(rows)} rows identical at 4 dp "
          "(run under JAX_ENABLE_X64=1 for 1e-5 parity)")

    print("\n== 5. Experiment API: fault-fraction x planes grid, "
          "cached ==")
    exp = Experiment(
        name="demo_fault_planes",
        base="allreduce_under_random_failures",
        axes=product(Axis("faults[0].frac", (0.05, 0.2)),
                     Axis("topo.n_planes", (1, 2)),
                     Axis("sim.slots", (160,))))
    with tempfile.TemporaryDirectory() as cache_dir:
        t0 = time.perf_counter()
        rs = run_experiment(exp, cache=cache_dir)
        t_cold = time.perf_counter() - t0
        print(f"  cold: {len(rs)} points in {t_cold:.2f}s "
              f"(hits={rs.cache_hits} misses={rs.cache_misses})")
        # WAR holds the ring at line rate through both fault levels (the
        # §6.4 claim); the §5.1 symmetry check degrades with fail frac
        goodput = rs.pivot("axis.faults[0].frac", "axis.topo.n_planes",
                           "mean_goodput")
        sym = rs.pivot("axis.faults[0].frac", "axis.topo.n_planes",
                       "symmetry_cv")
        for frac in sorted(goodput):
            cells = ", ".join(
                f"planes={p}: bw={goodput[frac][p]:.3f} "
                f"sym_cv={sym[frac][p]:.3f}"
                for p in sorted(goodput[frac]))
            print(f"  fail_frac={frac:4.2f} -> {cells}")
        t0 = time.perf_counter()
        rs2 = run_experiment(exp, cache=cache_dir)
        t_warm = time.perf_counter() - t0
        print(f"  warm: hits={rs2.cache_hits} misses={rs2.cache_misses} "
              f"in {t_warm:.2f}s — an interrupted grid resumes the same "
              "way (completed points stream into the cache as they "
              "finish)")


if __name__ == "__main__":
    main()
